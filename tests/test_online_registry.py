"""Model registry tests: atomic versioned publish/load round-trips,
promote/rollback lifecycle, digest verification, and the fleet shipping model
versions instead of raw training traces."""

import json

import numpy as np
import pytest

from repro.cluster.fleet import SweepSpec, run_sweep, sweep_json
from repro.core.predictor import TaskPredictor
from repro.online.registry import ModelRegistry


def _trained_predictor(seed=0, flip=False):
    rng = np.random.RandomState(seed)
    X = rng.rand(300, 8).astype(np.float32)
    y = (X[:, 0] > 0.5).astype(np.float32)
    if flip:
        y = 1 - y
    pred = TaskPredictor(algo="R.F.", min_samples=50, seed=seed)
    pred.fit_datasets((X, y), (X, 1 - y))
    return pred, X


# ---------------------------------------------------------------------------
# Snapshot + registry round trips
# ---------------------------------------------------------------------------

def test_snapshot_roundtrip_scores_bitwise():
    pred, X = _trained_predictor()
    other = TaskPredictor().load_snapshot(pred.snapshot())
    for kind in ("map", "reduce"):
        assert np.array_equal(pred.predict_batch(kind, X),
                              other.predict_batch(kind, X))
    assert other.fits == pred.fits


def test_registry_publish_load_roundtrip(tmp_path):
    pred, X = _trained_predictor()
    reg = ModelRegistry(tmp_path)
    v = reg.publish("fifo/baseline/smoke/s0", pred.snapshot(),
                    meta={"role": "train"})
    assert v == 1
    snap = reg.load("fifo/baseline/smoke/s0")
    other = TaskPredictor().load_snapshot(snap)
    for kind in ("map", "reduce"):
        assert np.array_equal(pred.predict_batch(kind, X),
                              other.predict_batch(kind, X))
    # layout: version dir with meta + params, HEAD, events ledger
    assert (tmp_path / "fifo/baseline/smoke/s0/v_000001/meta.json").exists()
    assert (tmp_path / "fifo/baseline/smoke/s0/HEAD").read_text() == "1"
    events = reg.history("fifo/baseline/smoke/s0")
    assert [e["event"] for e in events] == ["publish"]


def test_registry_versioning_promote_rollback(tmp_path):
    reg = ModelRegistry(tmp_path)
    p1, _ = _trained_predictor(seed=1)
    p2, _ = _trained_predictor(seed=2)
    assert reg.publish("m", p1.snapshot()) == 1
    assert reg.publish("m", p2.snapshot()) == 2
    assert reg.versions("m") == [1, 2]
    assert reg.head("m") == 2
    assert reg.rollback("m") == 1
    assert reg.head("m") == 1
    assert reg.load("m")["seed"] == 1           # HEAD serves v1 again
    reg.promote("m", 2)
    assert reg.head("m") == 2
    assert [e["event"] for e in reg.history("m")] == \
        ["publish", "publish", "rollback", "promote"]
    with pytest.raises(KeyError):
        reg.promote("m", 99)


def test_registry_archived_candidate_does_not_move_head(tmp_path):
    reg = ModelRegistry(tmp_path)
    p1, _ = _trained_predictor(seed=1)
    p2, _ = _trained_predictor(seed=2)
    reg.publish("m", p1.snapshot())
    v = reg.publish("m", p2.snapshot(), promote=False)
    assert v == 2 and reg.head("m") == 1
    assert reg.load("m", version=2)["seed"] == 2   # still loadable explicitly


def test_registry_detects_corruption(tmp_path):
    pred, _ = _trained_predictor()
    reg = ModelRegistry(tmp_path)
    reg.publish("m", pred.snapshot())
    meta_path = tmp_path / "m/v_000001/meta.json"
    meta = json.loads(meta_path.read_text())
    meta["digests"]["map__leaves"] = "0" * 16
    meta_path.write_text(json.dumps(meta))
    with pytest.raises(IOError, match="digest mismatch"):
        reg.load("m")


def test_non_forest_snapshot_rejected():
    rng = np.random.RandomState(0)
    X = rng.rand(300, 8).astype(np.float32)
    y = (X[:, 0] > 0.5).astype(np.float32)
    pred = TaskPredictor(algo="Glm", min_samples=50)
    pred.fit_datasets((X, y), (X, y))
    with pytest.raises(ValueError, match="registry-serialisable"):
        pred.snapshot()


# ---------------------------------------------------------------------------
# Fleet integration: model versions replace raw trace arrays
# ---------------------------------------------------------------------------

def test_fleet_registry_mode_matches_dataset_mode(tmp_path):
    spec = SweepSpec(schedulers=("atlas-fifo",), seeds=2,
                     scenarios=("baseline",), workloads=("smoke",),
                     min_samples=40, max_train=40)
    plain = run_sweep(spec, executor="serial", log=lambda *a: None)
    via_registry = run_sweep(spec, executor="serial",
                             registry=str(tmp_path), log=lambda *a: None)
    assert sweep_json(plain) == sweep_json(via_registry)
    reg = ModelRegistry(tmp_path)
    assert reg.versions("fifo/baseline/smoke/s0") == [1]
    assert reg.versions("fifo/baseline/smoke/s1") == [1]
