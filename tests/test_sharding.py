"""Distribution-layer tests: rule resolution, spec building, and a miniature
end-to-end sharded train step on a small host mesh (fast — no 512-dev compile;
the full grid is covered by launch/dryrun.py artifacts)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_arch, smoke_reduce, cell_supported
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import arch_rules, batch_specs, build_cell
from repro.parallel.axes import logical_to_spec, make_rules


def _mesh22():
    """Rule-resolution tests only read axis names — an AbstractMesh needs no
    devices, so these run on a single-device host too."""
    if jax.device_count() >= 4:
        return jax.make_mesh((2, 2), ("data", "model"))
    try:
        return jax.sharding.AbstractMesh((2, 2), ("data", "model"))
    except TypeError:   # jax<=0.4.37 signature: tuple of (name, size) pairs
        return jax.sharding.AbstractMesh((("data", 2), ("model", 2)))


def test_rules_resolution_basics():
    mesh = _mesh22()
    rules = make_rules()
    assert logical_to_spec(("batch", "seq"), rules, mesh) == P("data", None)
    assert logical_to_spec(("embed", "ff"), rules, mesh) == P(None, "model")
    # 'pod' dropped on single-pod meshes
    assert logical_to_spec(("batch",), rules, mesh) == P("data")


def test_rules_no_duplicate_mesh_axes():
    mesh = _mesh22()
    rules = make_rules(fsdp=True)
    # embed->data, but batch already used data: second use must drop
    spec = logical_to_spec(("batch", "embed"), rules, mesh)
    flat = [a for part in spec if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(flat) == len(set(flat))


def test_fsdp_rules_shard_embed():
    mesh = _mesh22()
    rules = make_rules(fsdp=True)
    assert logical_to_spec(("embed", "ff"), mesh=mesh, rules=rules) == \
        P("data", "model")


def test_decode_rules_shard_kv_seq():
    arch = get_arch("stablelm-1.6b")
    mesh = _mesh22()
    rules = arch_rules(arch, SHAPES["decode_32k"], mesh)
    spec = logical_to_spec(("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                           rules, mesh)
    assert spec[2] == "model"  # cache sequence dim sharded over model


def test_long_context_rules_sequence_parallel():
    arch = get_arch("rwkv6-1.6b")
    mesh = _mesh22()
    rules = arch_rules(arch, SHAPES["long_500k"], mesh)
    assert rules["batch"] is None  # batch=1 cannot shard


def test_cell_supported_matrix():
    grid = [(a, s) for a in ("stablelm-12b", "rwkv6-1.6b", "zamba2-1.2b")
            for s in SHAPES.values()]
    results = {(a, s.name): cell_supported(get_arch(a), s)[0] for a, s in grid}
    assert results[("stablelm-12b", "long_500k")] is False
    assert results[("rwkv6-1.6b", "long_500k")] is True
    assert results[("zamba2-1.2b", "long_500k")] is True
    assert all(results[(a, s)] for a in ("stablelm-12b", "rwkv6-1.6b")
               for s in ("train_4k", "prefill_32k", "decode_32k"))


def test_production_mesh_shapes():
    # uses however many host devices exist; only the *structure* is asserted via
    # the axis names (actual 256/512-dev construction happens in dryrun.py)
    try:
        mesh = make_production_mesh()
    except ValueError:
        pytest.skip("not enough host devices outside the dryrun environment")
    assert mesh.axis_names == ("data", "model")


@pytest.mark.parametrize("arch_id", ["stablelm-1.6b", "deepseek-moe-16b",
                                     "rwkv6-1.6b", "zamba2-1.2b"])
def test_sharded_train_step_matches_unsharded(arch_id):
    """The same reduced config, same batch: train step on a (2,2) mesh must match
    the single-device step numerically (the sharding is semantics-preserving)."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 host devices (tests/conftest sets 8)")
    arch = smoke_reduce(get_arch(arch_id))
    arch = dataclasses.replace(arch, accum_steps=1)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=4)

    from repro.models.steps import init_train_state, make_train_step
    from repro.optim import AdamWConfig
    opt = AdamWConfig(warmup_steps=1, total_steps=4)

    # unsharded
    step_fn, _ = make_train_step(arch, opt)
    state0 = init_train_state(arch, jax.random.PRNGKey(0), opt)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                arch.vocab_size, jnp.int32)
    _, m_ref = jax.jit(step_fn)(state0, {"tokens": tokens})

    # sharded
    mesh = _mesh22()
    with mesh:
        cell = build_cell(arch, shape, mesh)
        jitted = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                         out_shardings=cell["out_shardings"])
        state1 = init_train_state(arch, jax.random.PRNGKey(0), opt)
        _, m_sh = jitted(state1, {"tokens": tokens})
    np.testing.assert_allclose(float(m_sh["loss"]), float(m_ref["loss"]),
                               rtol=5e-3, atol=5e-4)


def test_batch_specs_shapes():
    arch = get_arch("llama-3.2-vision-90b")
    b = batch_specs(arch, SHAPES["train_4k"])
    assert b["tokens"].shape == (256, 4096)
    assert b["media"].shape == (256, 1024, 8192)
    d = batch_specs(arch, SHAPES["decode_32k"])
    assert d["tokens"].shape == (128, 1)
    assert d["pos"].shape == (128,)
