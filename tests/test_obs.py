"""Observability layer tests: metrics-core semantics and determinism, ring
wraparound, NDJSON round-trip, per-tick simulator frames, SWEEP byte-parity
with telemetry on vs off, the vectorised broker memo hash, the telemetry job
ledger, and the ops dashboard renderer."""

import json

import numpy as np
import pytest

from repro.cluster.chaos import ChaosConfig
from repro.cluster.experiment import ExperimentConfig, run_scheduler
from repro.cluster.fleet import SweepSpec, run_sweep, sweep_json
from repro.cluster.workload import WorkloadConfig
from repro.obs import (BrokerObserver, MemorySink, MetricsRegistry,
                       NDJSONSink, SimObserver, percentile_from_hist,
                       read_ndjson)
from repro.obs.dashboard import main as dashboard_main
from repro.obs.dashboard import render_html
from repro.online.broker import feature_hashes


# ---------------------------------------------------------------------------
# Metrics core
# ---------------------------------------------------------------------------

def test_registry_handles_and_snapshot():
    m = MetricsRegistry()
    h_c = m.counter("a.count")
    h_g = m.gauge("a.gauge")
    h_h = m.histogram("a.hist", (1, 2, 4))
    m.freeze()
    m.inc(h_c)
    m.inc(h_c, 3)
    m.set(h_g, 0.75)
    m.observe(h_h, 1.5)
    snap = m.snapshot()
    assert snap["counters"]["a.count"] == 4
    assert snap["gauges"]["a.gauge"] == 0.75
    assert sum(snap["histograms"]["a.hist"]["counts"]) == 1


def test_registry_is_static():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(ValueError):
        m.counter("x")                    # duplicate name
    m.freeze()
    with pytest.raises(RuntimeError):
        m.counter("y")                    # registration after freeze


def test_histogram_bucket_semantics():
    """Upper-edge buckets with side='left': value == edge lands IN that
    bucket; values past the last edge land in the overflow bucket."""
    m = MetricsRegistry()
    h = m.histogram("h", (1, 2, 4))
    m.freeze()
    for v, bucket in ((0.5, 0), (1.0, 0), (1.5, 1), (2.0, 1), (4.0, 2),
                      (4.5, 3), (100.0, 3)):
        before = list(m.hist_counts[h])
        m.observe(h, v)
        deltas = [a - b for a, b in zip(m.hist_counts[h], before)]
        assert deltas[bucket] == 1, (v, bucket)


def test_observe_many_matches_scalar_path():
    vals = np.array([0.1, 1.0, 1.1, 3.9, 4.0, 7.0, 1e9])
    a, b = MetricsRegistry(), MetricsRegistry()
    ha = a.histogram("h", (1, 2, 4))
    hb = b.histogram("h", (1, 2, 4))
    a.freeze(), b.freeze()
    for v in vals:
        a.observe(ha, float(v))
    b.observe_many(hb, vals)
    assert np.array_equal(a.hist_counts[ha], b.hist_counts[hb])


def test_ring_wraparound_keeps_newest_oldest_first():
    m = MetricsRegistry(ring_capacity=4)
    h = m.counter("c")
    m.freeze()
    for t in range(6):                    # 6 ticks into a 4-slot ring
        m.inc(h, 10)
        m.tick(float(t))
    times, counters, _ = m.ring()
    assert times.tolist() == [2.0, 3.0, 4.0, 5.0]
    assert counters[:, h].tolist() == [30, 40, 50, 60]
    assert m.deltas(h).tolist() == [0, 10, 10, 10]   # first delta anchors at 0
    assert m.n_ticks == 6


def test_metrics_deterministic_replay():
    def build():
        m = MetricsRegistry()
        hc, hg = m.counter("c"), m.gauge("g")
        hh = m.histogram("h", (1, 10, 100))
        m.freeze()
        rng = np.random.default_rng(7)
        for _ in range(200):
            m.inc(hc, int(rng.integers(1, 5)))
            m.set(hg, float(rng.random()))
            m.observe(hh, float(rng.random() * 200))
        return json.dumps(m.snapshot(), sort_keys=True)
    assert build() == build()


def test_percentile_from_hist():
    edges = np.array([1.0, 2.0, 4.0])
    counts = np.array([10, 0, 0, 0])
    assert percentile_from_hist(edges, counts, 0.5) == 1.0
    counts = np.array([5, 5, 0, 0])
    assert percentile_from_hist(edges, counts, 0.99) == 2.0
    assert percentile_from_hist(edges, np.array([0, 0, 0, 10]), 0.5) == 4.0
    assert percentile_from_hist(edges, np.zeros(4, int), 0.5) == 0.0


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------

def test_ndjson_roundtrip(tmp_path):
    frames = [{"type": "meta", "t": 0.0, "n": 3},
              {"type": "frame", "t": 1.5, "occ": [0.1, 0.2]},
              {"type": "final", "t": 2.0, "nested": {"a": [1, 2]}}]
    p = tmp_path / "sub" / "frames.ndjson"   # parent dir auto-created
    sink = NDJSONSink(p)
    for f in frames:
        sink.emit(f)
    sink.close()
    assert sink.n_frames == 3
    assert read_ndjson(p) == frames
    assert read_ndjson(tmp_path / "missing.ndjson") == []


# ---------------------------------------------------------------------------
# Simulator instrumentation
# ---------------------------------------------------------------------------

def _cfg(**kw):
    base = dict(
        workload=WorkloadConfig(n_single=10, n_chains=2, seed=5),
        chaos=ChaosConfig(intensity=2.0, seed=6),
        seed=3, min_samples=32, max_train=256)
    base.update(kw)
    return ExperimentConfig(**base)


@pytest.fixture(scope="module")
def fifo_obs_run(tmp_path_factory):
    path = tmp_path_factory.mktemp("obs") / "fifo.ndjson"
    cfg = _cfg(obs_path=str(path), obs_frame_every=120.0)
    metrics, trace, sim = run_scheduler("fifo", cfg)
    return path, metrics, trace, sim


def test_sim_observer_streams_frames(fifo_obs_run):
    path, metrics, trace, sim = fifo_obs_run
    frames = read_ndjson(path)
    assert frames[0]["type"] == "meta"
    assert frames[0]["n_nodes"] == len(sim.nodes)
    assert frames[-1]["type"] == "final"
    body = [f for f in frames if f["type"] == "frame"]
    assert body, "no per-tick frames emitted"
    ts = [f["t"] for f in body]
    assert ts == sorted(ts)
    for f in body:
        assert 0.0 <= f["occ"] <= 1.0
        assert len(f["node_occ"]) == len(sim.nodes)
        assert all(d >= 0 for d in f["node_fail"])
    # the deterministic roll-up is stamped into the run metrics
    obs = metrics["obs"]
    assert obs["frames"] == len(body)
    assert obs["events"]["submit"] > 0
    assert obs["events"]["heartbeat"] > 0


def test_obs_never_changes_sim_results(fifo_obs_run):
    """Telemetry on vs off: identical metrics (the observer only reads)."""
    path, metrics, _, _ = fifo_obs_run
    plain, _, _ = run_scheduler("fifo", _cfg())
    instrumented = {k: v for k, v in metrics.items() if k != "obs"}
    assert instrumented == plain


def test_job_ledger_matches_job_table(fifo_obs_run):
    """The telemetry job ledger reproduces the sim.jobs rescan bit-for-bit."""
    _, _, trace, sim = fifo_obs_run
    assert set(trace.jobs) == set(sim.jobs)
    ledger = {jid: r for jid, r in trace.jobs.items()}
    for jid, job in sim.jobs.items():
        row = ledger[jid]
        assert row["submit"] == job.submit_time
        assert row["outcome"] == job.status
        if job.status == "finished":
            assert row["end"] == job.done_time
    times = trace.job_times()
    rescan = sorted(j.done_time - j.submit_time
                    for j in sim.jobs.values() if j.status == "finished")
    assert sorted(times) == rescan


# ---------------------------------------------------------------------------
# Fleet sweep parity: --obs must not move a single byte of SWEEP.json
# ---------------------------------------------------------------------------

def test_sweep_byte_parity_obs_on_vs_off(tmp_path):
    spec = SweepSpec(schedulers=("fifo", "atlas-fifo"), seeds=1,
                     scenarios=("baseline",), workloads=("smoke",))
    off = run_sweep(spec, executor="serial", log=lambda *a: None)
    on = run_sweep(spec, executor="serial", obs_dir=str(tmp_path / "obs"),
                   log=lambda *a: None)
    # telemetry roll-ups live ONLY under perf.obs
    obs_block = on["perf"].pop("obs")
    if not on["perf"]:
        on.pop("perf")
    assert sweep_json(on) == sweep_json(off)
    # ...and every requested cell streamed frames + landed a roll-up
    assert set(obs_block["cells"]) == {r["cell_id"] for r in off["cells"]}
    for cid in obs_block["cells"]:
        f = tmp_path / "obs" / (cid.replace("/", "__") + ".ndjson")
        assert f.exists() and read_ndjson(f)[-1]["type"] == "final"


# ---------------------------------------------------------------------------
# Broker: vectorised memo hash + flush observer
# ---------------------------------------------------------------------------

def test_feature_hashes_bit_pattern_semantics():
    rng = np.random.default_rng(0)
    X = rng.random((64, 22)).astype(np.float32)
    h1, h2 = feature_hashes(X)
    assert h1.shape == h2.shape == (64,)
    # same bits -> same key (the memo contract)
    g1, g2 = feature_hashes(X.copy())
    assert np.array_equal(h1, g1) and np.array_equal(h2, g2)
    # distinct rows -> distinct 128-bit keys
    keys = set(zip(h1.tolist(), h2.tolist()))
    assert len(keys) == 64
    # the hash keys raw float bits, exactly like the tobytes() it replaced:
    # -0.0 and +0.0 compare equal but are different keys
    z = np.zeros((1, 4), np.float32)
    nz = z.copy()
    nz[0, 0] = -0.0
    assert feature_hashes(z)[0][0] != feature_hashes(nz)[0][0]


def test_broker_observer_summary_and_frames():
    sink = MemorySink()
    obs = BrokerObserver(sink=sink)
    for rows, reqs, disp, lat in ((4, 2, 1, 0.2e-3), (16, 8, 2, 1.1e-3),
                                  (4, 2, 1, 0.4e-3)):
        obs.record_flush(rows, reqs, disp, lat)
    det = obs.summary(deterministic_only=True)
    assert det["broker.flushes"] == 3
    assert det["broker.rows"] == 24
    assert det["broker.dispatches"] == 4
    assert "flush_latency_ms" not in det      # wall clock never in stable out
    full = obs.summary()
    assert full["flush_latency_ms"]["p50"] > 0
    assert [f["rows"] for f in sink.frames] == [4, 16, 4]
    assert det["flush_rows_p50"] == 4.0


def test_sim_observer_memory_sink_collapses_idle_gaps():
    """Quiet periods collapse: frame count tracks boundaries crossed by
    events, never busy-waits through idle simulated time."""

    class _Node:
        def __init__(self):
            self.spec = type("S", (), {"map_slots": 2, "reduce_slots": 2,
                                       "name": "n"})()
            self.running_maps = 1
            self.running_reduces = 0
            self.last_heartbeat = 0.0
            self.failed_count = 0

    class _Sim:
        nodes = [_Node()]
        pending = ()
        n_running_jobs = 0
        heartbeat_interval = 600.0
        _known_alive = {0}
        scheduler = type("Sch", (), {
            "name": "fifo",
            "frame_stats": lambda self: {"penalty_box": 0, "pred": None},
        })()
        now = 0.0

    sink = MemorySink()
    obs = SimObserver(sink=sink, frame_every=10.0, min_events_per_frame=1)
    sim = _Sim()
    obs.bind(sim)
    for t in (1.0, 5.0, 12.0, 1000.0, 1001.0):   # long idle gap: 12 -> 1000
        sim.now = t
        obs.after_event(sim, 0)
    body = [f for f in sink.frames if f["type"] == "frame"]
    # one frame per crossing, stamped on the boundary grid: the 12 -> 1000
    # gap costs ONE frame (at the first missed boundary), not 98 of them
    assert [f["t"] for f in body] == [10.0, 20.0]
    sim.now = 1015.0
    obs.after_event(sim, 0)                      # next boundary is 1010
    assert [f["t"] for f in sink.frames if f["type"] == "frame"] \
        == [10.0, 20.0, 1010.0]

    # the density gate: boundary crossings alone don't emit — frames wait
    # for min_events_per_frame events, bounding telemetry work per event
    gated = SimObserver(sink=MemorySink(), frame_every=10.0,
                        min_events_per_frame=3)
    gated.bind(sim2 := _Sim())
    for t in (15.0, 30.0, 45.0, 60.0, 75.0, 90.0):   # every event crosses
        sim2.now = t
        gated.after_event(sim2, 0)
    assert gated._n_frames == 2                  # 6 events / gate of 3


# ---------------------------------------------------------------------------
# Dashboard
# ---------------------------------------------------------------------------

def test_dashboard_renders_all_sections(fifo_obs_run):
    path, _, _, _ = fifo_obs_run
    html = render_html(read_ndjson(path))
    assert html.lstrip().startswith("<!DOCTYPE html>")
    for needle in ("Fleet occupancy", "failure", "<svg", "viz-root",
                   "prefers-color-scheme: dark", "<details>"):
        assert needle in html, needle


def test_dashboard_cli(tmp_path, fifo_obs_run, capsys):
    path, _, _, _ = fifo_obs_run
    out = tmp_path / "dash.html"
    rc = dashboard_main([str(path), "-o", str(out)])
    assert rc == 0
    stat = json.loads(capsys.readouterr().out)
    assert stat["frames"] > 0 and out.stat().st_size == stat["bytes"]
    # no frames -> non-zero exit (the obs-smoke CI assertion)
    empty = tmp_path / "empty.ndjson"
    empty.write_text("")
    assert dashboard_main([str(empty), "-o", str(tmp_path / "x.html")]) == 2


def test_dashboard_rejects_empty_stream():
    with pytest.raises(ValueError):
        render_html([])
