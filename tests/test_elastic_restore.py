"""Elastic restore: a checkpoint written under one sharding restores onto a
different mesh (the fleet shrank/grew) — the TPU analogue of rescheduling onto
surviving TaskTrackers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >1 host device (run under forced device count)")
def test_restore_onto_smaller_mesh(tmp_path):
    devs = jax.devices()
    mesh_big = jax.make_mesh((len(devs),), ("data",))
    tree = {"w": jnp.arange(len(devs) * 8, dtype=jnp.float32).reshape(
        len(devs) * 2, 4)}
    sharded = jax.device_put(tree["w"], NamedSharding(mesh_big, P("data", None)))

    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(1, {"w": sharded})

    # "fleet shrank": restore onto half the devices
    half = jax.make_mesh((max(len(devs) // 2, 1),), ("data",))
    shardings = {"w": NamedSharding(half, P("data", None))}
    got = mgr.restore(1, {"w": sharded}, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert got["w"].sharding.mesh.devices.size == half.devices.size


def test_restore_replays_identical_training(tmp_path):
    """Determinism end-to-end: save at step k, keep training; restore at k and
    replay with the same data stream -> identical state at k+n."""
    import dataclasses
    from repro.configs import get_arch, smoke_reduce
    from repro.data import DataConfig, SyntheticStream
    from repro.models.steps import init_train_state, make_train_step
    from repro.optim import AdamWConfig

    arch = smoke_reduce(get_arch("stablelm-1.6b"))
    arch = dataclasses.replace(arch, n_layers=2, d_model=64, d_ff=128,
                               vocab_size=128, n_heads=2, n_kv_heads=2,
                               head_dim=32)
    opt = AdamWConfig(warmup_steps=1, total_steps=10)
    step_fn = jax.jit(make_train_step(arch, opt)[0])
    stream = SyntheticStream(DataConfig(vocab_size=128, seq_len=32,
                                        global_batch=4, seed=0))
    mgr = CheckpointManager(tmp_path, async_write=False)

    state = init_train_state(arch, jax.random.PRNGKey(0), opt)
    for s in range(3):
        state, _ = step_fn(state, jax.tree.map(jnp.asarray, stream.batch(s, 0, 1)))
    mgr.save(3, state)
    for s in range(3, 6):
        state, _ = step_fn(state, jax.tree.map(jnp.asarray, stream.batch(s, 0, 1)))

    replay = mgr.restore(3, state)
    for s in range(3, 6):
        replay, _ = step_fn(replay, jax.tree.map(jnp.asarray,
                                                 stream.batch(s, 0, 1)))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(replay)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
