"""Property tests on the logical-axis rule system — the invariants the whole
distribution layer rests on."""

import jax
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.parallel.axes import DEFAULT_RULES, logical_to_spec, make_rules

LOGICAL = sorted(DEFAULT_RULES)


def _mesh(names=("data", "model")):
    try:
        return jax.sharding.AbstractMesh((2,) * len(names), names)
    except TypeError:   # jax<=0.4.37 signature: tuple of (name, size) pairs
        return jax.sharding.AbstractMesh(tuple((n, 2) for n in names))


@settings(max_examples=50, deadline=None)
@given(axes=st.lists(st.sampled_from(LOGICAL + [None]), min_size=0, max_size=6),
       fsdp=st.booleans(), kv=st.booleans(), sp=st.booleans())
def test_spec_never_reuses_a_mesh_axis(axes, fsdp, kv, sp):
    mesh = _mesh(("pod", "data", "model"))
    rules = make_rules(fsdp=fsdp, shard_kv_heads=kv, sequence_parallel=sp)
    spec = logical_to_spec(tuple(axes), rules, mesh)
    used = []
    for part in spec:
        if part is None:
            continue
        for a in (part if isinstance(part, tuple) else (part,)):
            used.append(a)
    assert len(used) == len(set(used)), (axes, spec)
    assert len(spec) == len(axes)


@settings(max_examples=30, deadline=None)
@given(axes=st.lists(st.sampled_from(LOGICAL), min_size=1, max_size=4))
def test_spec_only_uses_existing_mesh_axes(axes):
    mesh = _mesh(("data", "model"))  # no 'pod'
    spec = logical_to_spec(tuple(axes), make_rules(), mesh)
    for part in spec:
        if part is None:
            continue
        for a in (part if isinstance(part, tuple) else (part,)):
            assert a in ("data", "model")


def test_unknown_logical_axis_replicates():
    mesh = _mesh()
    assert logical_to_spec(("no_such_axis",), make_rules(), mesh) == P(None)


@settings(max_examples=20, deadline=None)
@given(overrides=st.dictionaries(st.sampled_from(LOGICAL),
                                 st.sampled_from([None, "data", "model"]),
                                 max_size=4))
def test_overrides_take_effect(overrides):
    mesh = _mesh()
    rules = make_rules(overrides=overrides)
    for k, v in overrides.items():
        spec = logical_to_spec((k,), rules, mesh)
        if v is None:
            assert spec == P(None)
        else:
            assert spec == P(v)
