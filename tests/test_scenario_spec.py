"""Typed scenario-space API (PR 8): ScenarioSpec round-trips, bounds,
search moves, scoped registration, deprecated wrappers, and the shared
SchedulerStats schema."""

import dataclasses
import random

import pytest

from repro.cluster.chaos import ChaosConfig
from repro.cluster.scenarios import (CHAOS_BOUNDS, SCENARIOS, WEIGHT_FIELDS,
                                     WORKLOAD_BOUNDS, WORKLOAD_SHAPES,
                                     Scenario, ScenarioSpec, get_scenario,
                                     get_workload, get_workload_shape,
                                     make_spec, scenario_chaos,
                                     scenario_scope, workload_for_seed)


def _in_bound(value, b):
    if b.kind == "span":
        lo, hi = value
        return b.lo <= lo <= hi <= b.hi
    return b.lo <= value <= b.hi


# ---------------------------------------------------------------------------
# serialisation
# ---------------------------------------------------------------------------

def test_roundtrip_identity_named_scenarios():
    for name in SCENARIOS:
        spec = make_spec(name, "smoke")
        again = ScenarioSpec.from_dict(spec.to_dict())
        assert again == spec
        # tuple fields must come back as tuples, not JSON lists
        assert isinstance(again.chaos.burst_size, tuple)
        assert isinstance(again.workload.maps_range, tuple)


def test_from_dict_rejects_unknown_fields():
    d = make_spec("baseline").to_dict()
    d["chaos"]["warp_drive"] = 1.0
    with pytest.raises(ValueError, match="warp_drive"):
        ScenarioSpec.from_dict(d)


def test_validate_catches_bad_points():
    base = make_spec("baseline")
    overweight = dataclasses.replace(
        base, chaos=dataclasses.replace(base.chaos, kill_tt=0.9, net_slow=0.9))
    with pytest.raises(ValueError, match="weights sum"):
        overweight.validate()
    bad_span = dataclasses.replace(
        base, workload=dataclasses.replace(base.workload, maps_range=(9, 2)))
    with pytest.raises(ValueError, match="maps_range"):
        bad_span.validate()


# ---------------------------------------------------------------------------
# search moves
# ---------------------------------------------------------------------------

def test_perturb_deterministic_and_within_bounds():
    spec = make_spec("baseline", "smoke")
    for seed in range(25):
        a = spec.perturb(random.Random(seed))
        b = spec.perturb(random.Random(seed))
        assert a == b, "perturb must be a pure function of the rng state"
        for fname, bound in CHAOS_BOUNDS.items():
            if getattr(a.chaos, fname) != getattr(spec.chaos, fname):
                assert _in_bound(getattr(a.chaos, fname), bound), fname
        for fname, bound in WORKLOAD_BOUNDS.items():
            if getattr(a.workload, fname) != getattr(spec.workload, fname):
                assert _in_bound(getattr(a.workload, fname), bound), fname
        a.validate()


def test_sample_within_bounds_and_valid():
    for seed in range(25):
        s = ScenarioSpec.sample(random.Random(seed))
        for fname, bound in CHAOS_BOUNDS.items():
            if fname in WEIGHT_FIELDS:
                continue               # weights may be renormalised below lo
            assert _in_bound(getattr(s.chaos, fname), bound), fname
        for fname, bound in WORKLOAD_BOUNDS.items():
            assert _in_bound(getattr(s.workload, fname), bound), fname
        s.validate()
        assert sum(getattr(s.chaos, f) for f in WEIGHT_FIELDS) <= 1.0 + 1e-9


def test_perturb_moves_something():
    spec = make_spec("baseline", "smoke")
    assert any(spec.perturb(random.Random(s)) != spec for s in range(5))


# ---------------------------------------------------------------------------
# registries + scoped registration
# ---------------------------------------------------------------------------

def test_make_spec_combines_registries():
    spec = make_spec("bursty_tt", "map_heavy")
    assert spec.chaos == SCENARIOS["bursty_tt"].chaos
    assert spec.workload == WORKLOAD_SHAPES["map_heavy"]


def test_get_workload_unknown_lists_known():
    with pytest.raises(KeyError, match="smoke"):
        get_workload("nope")


def test_scenario_scope_registers_and_cleans_up():
    point = ScenarioSpec.sample(random.Random(3), name="synthetic-pt")
    with scenario_scope(point) as (s_name, w_name):
        assert s_name == w_name == "synthetic-pt"
        assert SCENARIOS[s_name] is point
        assert WORKLOAD_SHAPES[w_name] is point.workload
        assert make_spec(s_name, w_name) == dataclasses.replace(point)
    assert "synthetic-pt" not in SCENARIOS
    assert "synthetic-pt" not in WORKLOAD_SHAPES


def test_scenario_scope_rejects_collisions_and_cleans_on_error():
    point = ScenarioSpec.sample(random.Random(3), name="baseline")
    with pytest.raises(ValueError, match="already registered"):
        with scenario_scope(point):
            pass
    point2 = ScenarioSpec.sample(random.Random(4), name="synthetic-err")
    with pytest.raises(RuntimeError):
        with scenario_scope(point2):
            raise RuntimeError("boom")
    assert "synthetic-err" not in SCENARIOS


# ---------------------------------------------------------------------------
# deprecated pre-PR8 names: warn AND agree with the typed API
# ---------------------------------------------------------------------------

def test_scenario_subclass_warns():
    with pytest.deprecated_call():
        Scenario(name="x", description="", chaos=ChaosConfig())


def test_scenario_chaos_wrapper():
    with pytest.deprecated_call():
        old = scenario_chaos("bursty_tt", 17)
    assert old == get_scenario("bursty_tt").chaos_for_seed(17)


def test_get_workload_shape_wrapper():
    with pytest.deprecated_call():
        old = get_workload_shape("smoke")
    assert old == get_workload("smoke")


def test_workload_for_seed_wrapper():
    with pytest.deprecated_call():
        old = workload_for_seed("smoke", 99)
    assert old == make_spec("baseline", "smoke").workload_for_seed(99)


# ---------------------------------------------------------------------------
# SchedulerStats: one typed schema for all four schedulers
# ---------------------------------------------------------------------------

def test_scheduler_stats_schema():
    from repro.sched.base import BASELINES, SchedulerStats
    for name, cls in BASELINES.items():
        stats = cls().stats()
        assert isinstance(stats, SchedulerStats)
        assert stats.to_dict() == {"launches": 0, "speculative_copies": 0}
        fs = cls().frame_stats()
        assert fs == {"penalty_box": 0, "pred": None}


def test_atlas_stats_extends_base_schema():
    from repro.core.atlas import ATLASScheduler, AtlasStats
    from repro.sched.base import BASELINES, SchedulerStats
    sched = ATLASScheduler(BASELINES["fifo"]())
    stats = sched.stats()
    assert isinstance(stats, AtlasStats)
    assert isinstance(stats, SchedulerStats)
    d = stats.to_dict()
    # exact historical metrics["atlas"] keys, in order (ledger compatibility)
    assert list(d) == ["launches", "speculative_copies", "predictions",
                      "predicted_fail", "relocations", "speculative_launches",
                      "penalties", "dead_probes", "hb_adjustments",
                      "model_fits"]
    # refresher trio appears only when a drift refresher is attached
    assert "refreshes" not in d
    fs = sched.frame_stats()
    assert fs["penalty_box"] == 0
    assert set(fs["pred"]) >= {"dispatches", "rows"}
