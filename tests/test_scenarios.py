"""Scenario library tests: registry integrity, seed injection, and that the
named regimes actually express distinct failure mixes."""

import dataclasses

import pytest

from repro.cluster.chaos import ChaosConfig
from repro.cluster.scenarios import (SCENARIOS, WORKLOAD_SHAPES, get_scenario,
                                     get_workload_shape, scenario_chaos,
                                     workload_for_seed)

EXPECTED = {"baseline", "bursty_tt", "dn_loss", "slot_degradation", "net_flap",
            "rack_failure", "straggler_heavy", "kitchen_sink"}


def test_registry_has_the_eight_named_scenarios():
    assert EXPECTED <= set(SCENARIOS)
    for sc in SCENARIOS.values():
        assert sc.description
        assert isinstance(sc.chaos, ChaosConfig)


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_branch_weights_are_a_valid_distribution(name):
    c = SCENARIOS[name].chaos
    mass = c.kill_tt + c.suspend_tt + c.kill_dn + c.net_slow + c.net_drop
    assert 0.0 <= mass <= 1.0 + 1e-9          # residual mass = thread-kill
    assert c.intensity > 0
    assert c.mean_outage > 0
    lo, hi = c.burst_size
    assert 1 <= lo <= hi


def test_scenarios_are_pairwise_distinct():
    configs = [dataclasses.replace(sc.chaos, seed=0)
               for sc in SCENARIOS.values()]
    assert len({repr(c) for c in configs}) == len(configs)


def test_baseline_matches_paper_default():
    assert dataclasses.replace(SCENARIOS["baseline"].chaos, seed=0) == \
        dataclasses.replace(ChaosConfig(), seed=0)


def test_seed_injection_leaves_template_untouched():
    c1 = scenario_chaos("bursty_tt", 11)
    c2 = scenario_chaos("bursty_tt", 22)
    assert c1.seed == 11 and c2.seed == 22
    assert dataclasses.replace(c1, seed=0) == dataclasses.replace(c2, seed=0)
    assert SCENARIOS["bursty_tt"].chaos.seed == ChaosConfig().seed


def test_unknown_names_raise_with_known_list():
    with pytest.raises(KeyError, match="baseline"):
        get_scenario("nope")
    with pytest.raises(KeyError, match="smoke"):
        get_workload_shape("nope")


def test_workload_shapes_registry():
    assert {"default", "smoke"} <= set(WORKLOAD_SHAPES)
    smoke = get_workload_shape("smoke")
    default = get_workload_shape("default")
    assert smoke.n_single < default.n_single     # smoke is genuinely small
    w = workload_for_seed("smoke", 99)
    assert w.seed == 99
    assert WORKLOAD_SHAPES["smoke"].seed != 99   # template untouched


# ---------------------------------------------------------------------------
# Per-node hazard scaling (PR 7)
# ---------------------------------------------------------------------------

def test_per_node_hazard_scales_event_rate_with_fleet_size():
    """At the reference fleet both modes are identical; at 10x the nodes the
    per-node mode draws ~10x shorter mean interarrivals while the cluster
    mode is unchanged — so per-node failure *rates* stay comparable across
    fleet sizes."""
    from repro.cluster.chaos import REFERENCE_FLEET, ChaosInjector

    class _Sim:
        def __init__(self, n):
            self.nodes = list(range(n))

    def scale(n, hazard):
        inj = ChaosInjector(ChaosConfig(hazard=hazard))
        inj.sim = _Sim(n)
        return inj.hazard_scale()

    assert scale(REFERENCE_FLEET, "cluster") == 1.0
    assert scale(REFERENCE_FLEET, "per-node") == 1.0
    assert scale(10 * REFERENCE_FLEET, "cluster") == 1.0
    assert scale(10 * REFERENCE_FLEET, "per-node") == 10.0
    # mean sampled interarrival follows the scale (same seed, same draws)
    class _PushSim(_Sim):
        now = 0.0

        def __init__(self, n):
            super().__init__(n)
            self.dts = []

        def _push(self, t, ev, payload):
            self.dts.append(t)

    def mean_dt(n, hazard, draws=400):
        inj = ChaosInjector(ChaosConfig(hazard=hazard, seed=7))
        sim = _PushSim(n)
        inj.bind(sim)
        for _ in range(draws):
            inj._schedule_next()
        return sum(sim.dts) / draws

    base = mean_dt(REFERENCE_FLEET, "per-node")
    scaled = mean_dt(10 * REFERENCE_FLEET, "per-node")
    assert scaled == pytest.approx(base / 10.0)
    assert mean_dt(10 * REFERENCE_FLEET, "cluster") == pytest.approx(base)


def test_unknown_hazard_mode_rejected():
    from repro.cluster.chaos import ChaosInjector

    with pytest.raises(ValueError, match="hazard"):
        ChaosInjector(ChaosConfig(hazard="per-rack"))


def test_cluster_hazard_default_keeps_scenario_bytes():
    """hazard='cluster' is the default everywhere: existing scenario chaos
    configs are untouched, so historical SWEEP bytes cannot move."""
    for name in SCENARIOS:
        assert get_scenario(name).chaos.hazard == "cluster"
