"""Scenario library tests: registry integrity, seed injection, and that the
named regimes actually express distinct failure mixes."""

import dataclasses

import pytest

from repro.cluster.chaos import ChaosConfig
from repro.cluster.scenarios import (SCENARIOS, WORKLOAD_SHAPES, get_scenario,
                                     get_workload_shape, scenario_chaos,
                                     workload_for_seed)

EXPECTED = {"baseline", "bursty_tt", "dn_loss", "slot_degradation", "net_flap",
            "rack_failure", "straggler_heavy", "kitchen_sink"}


def test_registry_has_the_eight_named_scenarios():
    assert EXPECTED <= set(SCENARIOS)
    for sc in SCENARIOS.values():
        assert sc.description
        assert isinstance(sc.chaos, ChaosConfig)


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_branch_weights_are_a_valid_distribution(name):
    c = SCENARIOS[name].chaos
    mass = c.kill_tt + c.suspend_tt + c.kill_dn + c.net_slow + c.net_drop
    assert 0.0 <= mass <= 1.0 + 1e-9          # residual mass = thread-kill
    assert c.intensity > 0
    assert c.mean_outage > 0
    lo, hi = c.burst_size
    assert 1 <= lo <= hi


def test_scenarios_are_pairwise_distinct():
    configs = [dataclasses.replace(sc.chaos, seed=0)
               for sc in SCENARIOS.values()]
    assert len({repr(c) for c in configs}) == len(configs)


def test_baseline_matches_paper_default():
    assert dataclasses.replace(SCENARIOS["baseline"].chaos, seed=0) == \
        dataclasses.replace(ChaosConfig(), seed=0)


def test_seed_injection_leaves_template_untouched():
    c1 = scenario_chaos("bursty_tt", 11)
    c2 = scenario_chaos("bursty_tt", 22)
    assert c1.seed == 11 and c2.seed == 22
    assert dataclasses.replace(c1, seed=0) == dataclasses.replace(c2, seed=0)
    assert SCENARIOS["bursty_tt"].chaos.seed == ChaosConfig().seed


def test_unknown_names_raise_with_known_list():
    with pytest.raises(KeyError, match="baseline"):
        get_scenario("nope")
    with pytest.raises(KeyError, match="smoke"):
        get_workload_shape("nope")


def test_workload_shapes_registry():
    assert {"default", "smoke"} <= set(WORKLOAD_SHAPES)
    smoke = get_workload_shape("smoke")
    default = get_workload_shape("default")
    assert smoke.n_single < default.n_single     # smoke is genuinely small
    w = workload_for_seed("smoke", 99)
    assert w.seed == 99
    assert WORKLOAD_SHAPES["smoke"].seed != 99   # template untouched
