"""Quickstart: train a small LM end-to-end with the ATLAS elastic runtime.

    PYTHONPATH=src python examples/quickstart.py [--steps 60] [--d-model 256]

Shows the whole public API in ~30 lines: pick an architecture config, reduce it,
build a data stream, and let the ATLAS-driven trainer run it with failure
injection, speculative shard duplication and hazard-driven checkpoints.
CPU-sized by default; on real hardware raise --d-model/--layers (e.g. 768/12
~ 100M params) and point --ckpt at durable storage."""

import argparse
import dataclasses
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_arch, smoke_reduce  # noqa: E402
from repro.data import DataConfig  # noqa: E402
from repro.runtime import ElasticTrainer, RuntimeConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--hosts", type=int, default=6)
    ap.add_argument("--fail-rate", type=float, default=0.02)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    arch = smoke_reduce(get_arch(args.arch))
    arch = dataclasses.replace(
        arch, d_model=args.d_model, n_layers=args.layers,
        vocab_size=args.vocab, d_ff=args.d_model * 3,
        head_dim=max(32, args.d_model // 4))
    print(f"arch: {arch.name}  layers={arch.n_layers} d_model={arch.d_model}")

    rcfg = RuntimeConfig(n_hosts=args.hosts, steps=args.steps,
                         fail_rate=args.fail_rate, checkpoint_every=10,
                         atlas=True, seed=0)
    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="atlas_ckpt_")
    trainer = ElasticTrainer(
        arch, rcfg, ckpt_dir,
        data_cfg=DataConfig(vocab_size=arch.vocab_size, seq_len=128,
                            global_batch=args.hosts * 2))
    out = trainer.run()
    print("\n== result ==")
    for k, v in out.items():
        print(f"  {k}: {v}")
    print(f"\nloss: {out['first_loss']:.3f} -> {out['final_loss']:.3f} over "
          f"{out['committed']} committed steps "
          f"({out['rollbacks']} rollbacks, {out['lost_steps']} lost steps)")


if __name__ == "__main__":
    main()
