"""Paper reproduction driver: the §5 case study on the simulated 15-node EMR
cluster — FIFO / Fair / Capacity vs ATLAS-<base>, with the paper's headline
claims printed next to ours.

    PYTHONPATH=src python examples/hadoop_sim.py [--seeds 2] [--intensity 5]
"""

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.cluster.chaos import ChaosConfig  # noqa: E402
from repro.cluster.experiment import ExperimentConfig, compare  # noqa: E402
from repro.cluster.workload import WorkloadConfig  # noqa: E402

PAPER = {
    "failed_jobs_drop_pct": 28.0,    # "up to 28%"
    "failed_tasks_drop_pct": 39.0,   # "up to 39%"
    "finished_jobs_gain_pct": 27.0,  # ATLAS-Fair
    "finished_tasks_gain_pct": 46.0, # ATLAS-Fair
    "job_time_matched_drop_pct": 30.0,  # ~10 min of ~20 (ATLAS-Capacity)
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--intensity", type=float, default=5.0)
    args = ap.parse_args()

    best = {k: -1e9 for k in PAPER}
    print(f"{'sched':10s} {'jobs_failed%':>14s} {'tasks_failed%':>14s} "
          f"{'exec_matched':>14s} {'deltas'}")
    for sched in ("fifo", "fair", "capacity"):
        ds = []
        for seed in range(args.seeds):
            cfg = ExperimentConfig(
                workload=WorkloadConfig(seed=7 + seed),
                chaos=ChaosConfig(intensity=args.intensity, seed=3 + seed),
                seed=seed)
            out = compare(sched, cfg)
            ds.append(out)
        b = {k: np.mean([d["base"][k] for d in ds])
             for k in ("pct_jobs_failed", "pct_tasks_failed",
                       "job_exec_time_matched")}
        a = {k: np.mean([d["atlas"][k] for d in ds])
             for k in ("pct_jobs_failed", "pct_tasks_failed",
                       "job_exec_time_matched")}
        deltas = {k: float(np.mean([d["deltas"][k] for d in ds]))
                  for k in ds[0]["deltas"]}
        for k in best:
            if k in deltas:
                best[k] = max(best[k], deltas[k])
        print(f"{sched:10s} {b['pct_jobs_failed']:6.1f}->{a['pct_jobs_failed']:5.1f} "
              f"{b['pct_tasks_failed']:7.1f}->{a['pct_tasks_failed']:5.1f} "
              f"{b['job_exec_time_matched']:6.0f}->{a['job_exec_time_matched']:5.0f}s "
              f" jobs↓{deltas['failed_jobs_drop_pct']:.0f}% "
              f"tasks↓{deltas['failed_tasks_drop_pct']:.0f}% "
              f"time↓{deltas['job_time_matched_drop_pct']:.0f}%")

    print("\n== paper claims vs this reproduction (best across schedulers) ==")
    for k, paper_v in PAPER.items():
        print(f"  {k:32s} paper: up to {paper_v:5.1f}%   ours: {best[k]:5.1f}%")


if __name__ == "__main__":
    main()
