"""Minimal fleet-sweep walkthrough: compare FIFO vs ATLAS-FIFO across two
failure regimes, then print the scenario library.

Run:  PYTHONPATH=src python examples/fleet_sweep.py

The ``__main__`` guard is required: the fleet's process pool uses the *spawn*
start method, which re-imports the launching script in each worker.
"""

from repro.cluster.fleet import SweepSpec, run_sweep, sweep_markdown
from repro.cluster.scenarios import SCENARIOS


def main():
    print("Scenario library:")
    for name, sc in sorted(SCENARIOS.items()):
        print(f"  {name:18s} {sc.description}")
    print()

    spec = SweepSpec(
        schedulers=("fifo", "atlas-fifo"),
        seeds=2,
        scenarios=("baseline", "dn_loss"),
        workloads=("smoke",),      # tiny mix; "default" is the paper's §5.1 mix
    )
    result = run_sweep(spec)       # parallel process pool by default
    print(sweep_markdown(result))


if __name__ == "__main__":
    main()
