"""End-to-end fault-tolerant training comparison: the SAME model, data and chaos
seed trained (a) with ATLAS placement/speculation/hazard-checkpoints and (b) with
plain periodic checkpointing — the training-fleet transposition of the paper's
Hadoop experiment.

    PYTHONPATH=src python examples/chaos_train.py [--steps 40] [--fail-rate 0.05]
"""

import argparse
import dataclasses
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_arch, smoke_reduce  # noqa: E402
from repro.data import DataConfig  # noqa: E402
from repro.runtime import ElasticTrainer, RuntimeConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--hosts", type=int, default=6)
    ap.add_argument("--fail-rate", type=float, default=0.05)
    ap.add_argument("--degrade-rate", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args()

    arch = smoke_reduce(get_arch("stablelm-1.6b"))
    arch = dataclasses.replace(arch, n_layers=2, d_model=64, d_ff=128,
                               vocab_size=512, n_heads=2, n_kv_heads=2,
                               head_dim=32)
    dc = DataConfig(vocab_size=arch.vocab_size, seq_len=64,
                    global_batch=args.hosts * 2)

    results = {}
    for atlas in (False, True):
        rcfg = RuntimeConfig(n_hosts=args.hosts, steps=args.steps,
                             fail_rate=args.fail_rate,
                             degrade_rate=args.degrade_rate,
                             checkpoint_every=5, atlas=atlas, seed=args.seed)
        with tempfile.TemporaryDirectory() as d:
            results[atlas] = ElasticTrainer(arch, rcfg, d, data_cfg=dc).run()

    print(f"{'metric':22s} {'baseline':>12s} {'ATLAS':>12s}")
    for k in ("committed", "lost_steps", "rollbacks", "duplicated_shards",
              "wasted_shards", "checkpoints", "hazard_checkpoints",
              "final_loss", "wall_s"):
        b, a = results[False][k], results[True][k]
        fmt = (lambda v: f"{v:.3f}") if isinstance(b, float) else str
        print(f"{k:22s} {fmt(b):>12s} {fmt(a):>12s}")
    print("\nATLAS trades a few duplicated shards for fewer lost steps/rollbacks "
          "— the paper's speculative-execution insurance, transposed to training.")


if __name__ == "__main__":
    main()
