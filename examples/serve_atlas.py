"""Serving example: batched prefill+decode with the KV-cache path, plus
ATLAS-style replica routing — requests are routed to the serving replica with the
best predicted health; a replica failure mid-decode fails over using the shared
prefix cache discipline (re-prefill on the survivor).

    PYTHONPATH=src python examples/serve_atlas.py [--tokens 16] [--batch 4]
"""

import argparse
import pathlib
import random
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_arch, smoke_reduce  # noqa: E402
from repro.models import get_model  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--kill-replica-at", type=int, default=6)
    args = ap.parse_args()

    arch = smoke_reduce(get_arch(args.arch))
    model = get_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.tokens

    # replicas = independent serving processes (same weights)
    health = [1.0] * args.replicas
    rng = random.Random(0)

    def pick_replica():
        # ATLAS-style: route to best predicted-health replica
        return max(range(args.replicas), key=lambda i: health[i])

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 arch.vocab_size, jnp.int32)

    decode = jax.jit(lambda p, c, t, pos: model.decode(p, c, t, pos))
    t0 = time.time()
    rep = pick_replica()
    logits, cache = model.prefill(params, prompts, max_len=max_len)
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    generated = [np.asarray(tok[:, 0])]
    failovers = 0
    for step in range(args.tokens - 1):
        if step == args.kill_replica_at and args.replicas > 1:
            health[rep] = 0.0  # replica dies: fail over
            new = pick_replica()
            if new != rep:
                failovers += 1
                rep = new
                # survivor re-prefills the full generated prefix (cache rebuild)
                ctx_tokens = jnp.concatenate(
                    [prompts, jnp.stack(generated, axis=1)], axis=1)
                logits, cache = model.prefill(params, ctx_tokens,
                                              max_len=max_len)
                pos = jnp.full((args.batch,), ctx_tokens.shape[1], jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        pos = pos + 1
        generated.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    total = args.batch * len(generated)
    print(f"served {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU, batch={args.batch}) "
          f"with {failovers} replica failover(s)")
    print("sample:", np.stack(generated, axis=1)[0][:12])


if __name__ == "__main__":
    main()
